#!/usr/bin/env bash
# CI gate: import-clean collection, fast kernel/sampler signal, then tier-1.
#
#   tools/ci.sh               # collection check + doc-tile/resume/serve
#                             # smokes + full tier-1 suite
#   tools/ci.sh --fast        # collection check + doc-tile/resume/serve
#                             # smokes + `-m "not slow"` subset only
#   tools/ci.sh --bench-smoke # benchmark smoke only: REPRO_BENCH_FAST=1
#                             # harness run (both token layouts; prints the
#                             # dense-vs-ragged pad_fraction delta), fails on
#                             # any ERROR row, then the BENCH_sweep.json
#                             # nomad regression gate (>30% tokens/sec drop
#                             # vs the previous same-methodology snapshot +
#                             # the interleaved B=4W ragged padding-blowup
#                             # canary)
#   tools/ci.sh --resume-smoke # checkpoint/resume smoke only: train k
#                             # rounds -> checkpoint -> kill -> resume,
#                             # assert the chain digest is bit-equal to
#                             # the uninterrupted run (also part of the
#                             # default and --fast stage lists)
#   tools/ci.sh --serve-smoke # serving smoke only: publish-while-serving
#                             # harness (launch/serve_check: >=3 publishes
#                             # interleaved with >=100 batched queries,
#                             # zero torn reads, batched==serial bit-exact,
#                             # every answer replayed through the other
#                             # inner mode) + a fused-inner-mode leg (the
#                             # Pallas fold-in kernel serves live, audited
#                             # against the scan path) + the fast
#                             # tests/test_serving.py subset (also part of
#                             # the default and --fast stage lists)
#   tools/ci.sh --chaos-smoke # fault-injection smoke only (DESIGN.md §11):
#                             # chaos_check matrix (kill + corrupted newest
#                             # rotation slot -> fallback resume bit-equal
#                             # to the straight run) + serve chaos (corrupt
#                             # / stale / format-skewed publishes refused,
#                             # query flood shed not queued, no invalid
#                             # generation served) — also part of the
#                             # default and --fast stage lists
#
# Property tests (tests/test_sharding_properties.py, ...) use `hypothesis`.
# CI servers should run with REPRO_CI_INSTALL_HYPOTHESIS=1 so the real
# package is installed and the tests run un-shimmed; without it (hermetic /
# offline containers) the deterministic shim in tests/conftest.py is used
# and a notice is printed.  We never install implicitly: offline images must
# not fail, and the shim keeps the suite green everywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

ensure_hypothesis() {
    if python -c "import hypothesis" 2>/dev/null; then
        echo "hypothesis: real package present (property tests un-shimmed)"
    elif [[ "${REPRO_CI_INSTALL_HYPOTHESIS:-}" == "1" ]]; then
        echo "hypothesis: installing (REPRO_CI_INSTALL_HYPOTHESIS=1)"
        # guard against set -e so the diagnostic fires on offline failures
        if ! python -m pip install --quiet hypothesis \
            || ! python -c "import hypothesis" 2>/dev/null; then
            echo "hypothesis: install failed"; return 1
        fi
    else
        echo "hypothesis: absent — property tests run under the" \
             "tests/conftest.py shim (set REPRO_CI_INSTALL_HYPOTHESIS=1" \
             "on CI to run them un-shimmed)"
    fi
}

bench_smoke() {
    echo "== bench smoke: REPRO_BENCH_FAST=1 python -m benchmarks.run =="
    local out
    out=$(REPRO_BENCH_FAST=1 python -m benchmarks.run) || {
        echo "$out"; echo "bench smoke: harness exited non-zero"; return 1; }
    echo "$out"
    if grep -q "ERROR" <<<"$out"; then
        echo "bench smoke: ERROR rows present"; return 1
    fi
    echo "== pad_fraction: dense vs ragged (from the smoke run) =="
    grep "sweep/pad_fraction" <<<"$out" \
        || echo "pad_fraction summary row missing (no nomad rows?)"
    echo "== bench regression gate: BENCH_sweep.json nomad trajectory =="
    python -m benchmarks.sweep_bench --check-regression
    echo "== serve regression gate: BENCH_serve.json docs/sec + canary =="
    python -m benchmarks.serve_bench --check-regression
}

serve_smoke() {
    # Publish-while-serving end to end (DESIGN.md §10): a background
    # nomad ring publishes >=3 snapshots into a live LdaEngine while
    # >=100 batched queries run against it; the harness audits zero
    # torn reads (every answer attributable to exactly one published
    # generation) and batched-vs-serial fold-in bit-exactness across
    # the whole run, then the fast serving test subset runs.
    echo "== serve smoke: publish-while-serving (launch/serve_check) =="
    local out
    out=$(python -m repro.launch.serve_check) || {
        echo "$out"; echo "serve smoke: check exited non-zero"; return 1; }
    python - "$out" <<'PY'
import json, sys
rep = json.loads(sys.argv[1].strip().splitlines()[-1])
print(f"serve smoke: {rep['publishes']} publishes, {rep['queries']} "
      f"queries across generations {rep['generations_seen']}, "
      f"{rep['torn_reads']} torn reads, "
      f"{rep['fold_in_mismatch']} fold-in mismatches, "
      f"{rep['cross_mode_mismatch']}/{rep['cross_mode_replays']} "
      f"cross-mode mismatches")
sys.exit(0 if rep["all_ok"] else 1)
PY
    # fused parity leg: the Pallas fold-in kernel serves live while the
    # audit replays every answer through the scan path (reduced query
    # floor — the kernel math is identical, only the wiring differs)
    echo "== serve smoke: fused inner mode (launch/serve_check --inner-mode fused) =="
    out=$(python -m repro.launch.serve_check --inner-mode fused \
        --queries 40) || {
        echo "$out"; echo "serve smoke: fused leg exited non-zero"
        return 1; }
    python - "$out" <<'PY'
import json, sys
rep = json.loads(sys.argv[1].strip().splitlines()[-1])
print(f"serve smoke [fused]: {rep['queries']} queries, "
      f"{rep['fold_in_mismatch']} fold-in mismatches, "
      f"{rep['cross_mode_mismatch']}/{rep['cross_mode_replays']} "
      f"fused-vs-scan mismatches")
sys.exit(0 if rep["all_ok"] else 1)
PY
    echo "== serve tests: tests/test_serving.py (-m 'not slow') =="
    python -m pytest -q -m "not slow" tests/test_serving.py
}

no_bytecode_tracked() {
    # Committed bytecode is a merge-conflict and staleness hazard; the
    # tree must never track __pycache__/ or *.pyc (see .gitignore).
    local tracked
    tracked=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$' || true)
    if [[ -n "$tracked" ]]; then
        echo "CI: compiled bytecode is tracked by git:"
        echo "$tracked"
        echo "run: git rm --cached <file> (patterns are in .gitignore)"
        return 1
    fi
    echo "no tracked bytecode (__pycache__/, *.pyc clean)"
}

resume_smoke() {
    # Preemption story end to end (DESIGN.md §9): train k rounds, write a
    # chain checkpoint, die abruptly (--kill: os._exit, no teardown),
    # resume from the checkpoint, and require the resumed chain's digest
    # to be bit-equal to an uninterrupted run of the same length.
    echo "== resume smoke: train -> checkpoint -> kill -> resume =="
    local tmpd straight resume
    tmpd=$(mktemp -d)
    trap 'rm -rf "$tmpd"' RETURN
    local common=(--n-devices 4 --n-blocks 8 --doc-tile 4 \
                  --layout ragged --r-mode sparse --sweeps 4)
    straight=$(python -m repro.launch.resume_check --phase straight \
        "${common[@]}" | tail -n 1) || {
        echo "resume smoke: straight phase failed"; return 1; }
    # the train phase self-kills after the checkpoint write (exit 137)
    python -m repro.launch.resume_check --phase train "${common[@]}" \
        --checkpoint-at 2 --ckpt "$tmpd/chain.npz" --kill || true
    [[ -f "$tmpd/chain.npz" ]] || {
        echo "resume smoke: no checkpoint written"; return 1; }
    resume=$(python -m repro.launch.resume_check --phase resume \
        "${common[@]}" --ckpt "$tmpd/chain.npz" | tail -n 1) || {
        echo "resume smoke: resume phase failed"; return 1; }
    python - "$straight" "$resume" <<'PY'
import json, sys
s, r = (json.loads(a) for a in sys.argv[1:3])
if s["digest"] != r["digest"]:
    print(f"resume smoke: chain forked across the kill\n"
          f"  straight {s['digest']}\n  resumed  {r['digest']}")
    sys.exit(1)
print(f"resume smoke: straight == kill+resume ({s['sweeps']} sweeps, "
      f"digest {s['digest'][:16]}...)")
PY
}

chaos_smoke() {
    # The failure model end to end (DESIGN.md §11), replayed from seeded
    # FaultPlans: (1) an in-process kill + a kill with the newest
    # rotation slot corrupted — both resumes must be bit-equal to the
    # straight run, the corrupted one via fallback to the previous valid
    # slot; (2) the serving engine under corrupt / stale-generation /
    # format-skewed publishes plus a query flood behind admission
    # control — every bad publish refused with its typed error, no
    # answer from an unaccepted generation, overload shed not queued.
    echo "== chaos smoke: kill + corrupt slot -> rotation fallback =="
    local out
    out=$(python -m repro.launch.chaos_check --phase matrix --fast) || {
        echo "$out"; echo "chaos smoke: matrix phase exited non-zero"
        return 1; }
    python - "$out" <<'PY'
import json, sys
rep = json.loads(sys.argv[1].strip().splitlines()[-1])
for c in rep["combos"]:
    print(f"chaos smoke [{c['damage']}]: killed={c['killed']} "
          f"slots={c['slots']} resumed_from={c['resumed_from_step']} "
          f"fell_back={c['fell_back']} exact={c['exact']}")
sys.exit(0 if rep["all_ok"] else 1)
PY
    echo "== chaos smoke: bad publishes + query flood (serve chaos) =="
    out=$(python -m repro.launch.chaos_check --phase serve --fast) || {
        echo "$out"; echo "chaos smoke: serve phase exited non-zero"
        return 1; }
    python - "$out" <<'PY'
import json, sys
rep = json.loads(sys.argv[1].strip().splitlines()[-1])
print(f"chaos smoke [serve]: {rep['publishes_accepted']} accepted / "
      f"{rep['publishes_rejected']} rejected publishes, "
      f"{rep['queries']} answers ({rep['degraded_answers']} degraded), "
      f"{rep['shed']} shed, "
      f"{rep['invalid_generation_answers']} invalid-generation answers, "
      f"max_pending_seen={rep['stats']['max_pending_seen']}")
sys.exit(0 if rep["all_ok"] else 1)
PY
}

echo "== hygiene: no compiled bytecode tracked by git =="
no_bytecode_tracked

if [[ "${1:-}" == "--bench-smoke" ]]; then
    bench_smoke
    echo "CI OK (bench smoke)"
    exit 0
fi

if [[ "${1:-}" == "--resume-smoke" ]]; then
    resume_smoke
    echo "CI OK (resume smoke)"
    exit 0
fi

if [[ "${1:-}" == "--serve-smoke" ]]; then
    serve_smoke
    echo "CI OK (serve smoke)"
    exit 0
fi

if [[ "${1:-}" == "--chaos-smoke" ]]; then
    chaos_smoke
    echo "CI OK (chaos smoke)"
    exit 0
fi

doc_tile_smoke() {
    # Doc-axis tiling + sparse-r regression signal (DESIGN.md §7/§7a):
    # the matrix check's smoke subset — paged vs untiled twins on both
    # layouts plus a sparse-r fused twin per ungrouped layout — and the
    # measured slab VMEM estimate, printed so silicon tuning has a
    # number to start from.
    echo "== doc-tile + sparse-r smoke: lda_matrix_check 4 1 smoke =="
    local out
    out=$(python -m repro.launch.lda_matrix_check 4 1 smoke) || {
        echo "$out"; echo "doc-tile smoke: check exited non-zero"
        return 1; }
    python - "$out" <<'PY'
import json, sys
# last stdout line is the report (stray XLA/absl lines may precede it)
rep = json.loads(sys.argv[1].strip().splitlines()[-1])
for s in rep["slab_vmem"]:
    print(f"doc-tile slab VMEM [{s['layout']} B={s['B']} "
          f"doc_tile={s['doc_tile']}]: slab {s['ntd_slab_bytes']} B vs "
          f"whole-shard {s['ntd_whole_bytes']} B "
          f"(fused call total {s['fused_vmem_bytes']} B)")
if not rep["all_exact"]:
    bad = [c for c in rep["combos"]
           if any(v for k, v in c.items() if k.endswith("mismatch"))]
    print("doc-tile smoke: INEXACT:", bad)
    sys.exit(1)
n_sparse = sum(c["r_mode"] == "sparse" for c in rep["combos"])
if not n_sparse:
    print("doc-tile smoke: no sparse-r combo in the smoke subset")
    sys.exit(1)
print(f"doc-tile smoke: {len(rep['combos'])} combos bit-exact, "
      f"{n_sparse} sparse-r "
      f"(paged == untiled == dense == ragged == dense-r)")
PY
}

ensure_hypothesis

echo "== collection (all test modules must import cleanly) =="
python -m pytest -q --collect-only >/dev/null

doc_tile_smoke

resume_smoke

serve_smoke

chaos_smoke

echo "== fast signal: kernels + samplers (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [[ "${1:-}" != "--fast" ]]; then
    # The fast subset already ran above; finish tier-1 with the remainder
    # instead of re-running everything.
    echo "== tier-1 remainder: slow suite (-m slow) =="
    python -m pytest -x -q -m "slow"
    bench_smoke
fi

echo "CI OK"
