#!/usr/bin/env bash
# CI gate: import-clean collection, fast kernel/sampler signal, then tier-1.
#
#   tools/ci.sh               # collection check + full tier-1 suite
#   tools/ci.sh --fast        # collection check + `-m "not slow"` subset only
#   tools/ci.sh --bench-smoke # benchmark smoke only: REPRO_BENCH_FAST=1
#                             # harness run, fails on any ERROR row
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

bench_smoke() {
    echo "== bench smoke: REPRO_BENCH_FAST=1 python -m benchmarks.run =="
    local out
    out=$(REPRO_BENCH_FAST=1 python -m benchmarks.run) || {
        echo "$out"; echo "bench smoke: harness exited non-zero"; return 1; }
    echo "$out"
    if grep -q "ERROR" <<<"$out"; then
        echo "bench smoke: ERROR rows present"; return 1
    fi
}

if [[ "${1:-}" == "--bench-smoke" ]]; then
    bench_smoke
    echo "CI OK (bench smoke)"
    exit 0
fi

echo "== collection (all test modules must import cleanly) =="
python -m pytest -q --collect-only >/dev/null

echo "== fast signal: kernels + samplers (-m 'not slow') =="
python -m pytest -q -m "not slow"

if [[ "${1:-}" != "--fast" ]]; then
    # The fast subset already ran above; finish tier-1 with the remainder
    # instead of re-running everything.
    echo "== tier-1 remainder: slow suite (-m slow) =="
    python -m pytest -x -q -m "slow"
    bench_smoke
fi

echo "CI OK"
