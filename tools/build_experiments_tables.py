"""Generate the §Dry-run and §Roofline markdown tables from reports/dryrun.

Usage: PYTHONPATH=src python tools/build_experiments_tables.py
Prints markdown to stdout (pasted into EXPERIMENTS.md).
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.analysis import model_flops  # noqa: E402

REPORTS = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["kimi-k2-1t-a32b", "gemma2-27b", "hubert-xlarge",
              "zamba2-2.7b", "internvl2-1b", "mamba2-1.3b",
              "phi4-mini-3.8b", "deepseek-moe-16b", "granite-3-2b",
              "qwen3-8b", "lda-fnomad"]


def fmt_t(x):
    return f"{x * 1e3:.2f}ms" if x >= 1e-4 else f"{x * 1e6:.1f}µs"


def fmt_b(x):
    if x >= 2**30:
        return f"{x / 2**30:.2f}GiB"
    return f"{x / 2**20:.1f}MiB"


def main():
    reps = {}
    for p in sorted(glob.glob(os.path.join(REPORTS, "*.json"))):
        r = json.load(open(p))
        key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
        reps[key] = r

    # ---- §Dry-run table ---------------------------------------------------
    print("### Dry-run status (lower + compile)\n")
    print("| arch | shape | 16×16 (256) | 2×16×16 (512) | "
          "peak bytes/dev (512) |")
    print("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        meshes = ("lda-256", "lda-512") if arch == "lda-fnomad" \
            else ("16x16", "2x16x16")
        for shape in SHAPE_ORDER:
            r1 = reps.get((arch, shape, meshes[0], "baseline"))
            r2 = reps.get((arch, shape, meshes[1], "baseline"))
            if r1 is None and r2 is None:
                continue

            def status(r):
                if r is None:
                    return "—"
                if "skipped" in r:
                    return "skip"
                if "error" in r:
                    return "ERROR"
                return f"ok ({r['compile_seconds']}s)"
            peak = "—"
            if r2 and "memory" in r2 and r2["memory"]["peak_bytes"]:
                peak = fmt_b(r2["memory"]["peak_bytes"])
            note = (r1 or r2).get("skipped", "") or (r1 or r2).get("note", "")
            print(f"| {arch} | {shape} | {status(r1)} | {status(r2)} | "
                  f"{peak} |" + (f"  <!-- {note} -->" if note else ""))
    print()

    # ---- §Roofline table (single-pod, baseline) ---------------------------
    print("### Roofline (single-pod 16×16, per-device terms)\n")
    print("| arch | shape | compute | memory | collective | bottleneck | "
          "useful-flops |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        mesh = "lda-256" if arch == "lda-fnomad" else "16x16"
        for shape in SHAPE_ORDER:
            r = reps.get((arch, shape, mesh, "baseline"))
            if r is None or "roofline_seconds" not in r:
                continue
            t = r["roofline_seconds"]
            mf = model_flops(arch, shape)
            hlo_glob = r["hlo_flops_per_device"] * r["chips"]
            useful = f"{mf / hlo_glob:.2f}" if hlo_glob and mf else "n/a"
            print(f"| {arch} | {shape} | {fmt_t(t['compute'])} | "
                  f"{fmt_t(t['memory'])} | {fmt_t(t['collective'])} | "
                  f"**{r['bottleneck']}** | {useful} |")
    print()

    # ---- variants (perf runs) ----------------------------------------------
    variants = sorted({k[3] for k in reps if k[3] != "baseline"})
    for v in variants:
        print(f"### Variant: {v}\n")
        print("| arch | shape | mesh | compute | memory | collective | "
              "bottleneck |")
        print("|---|---|---|---|---|---|---|")
        for (arch, shape, mesh, var), r in sorted(reps.items()):
            if var != v or "roofline_seconds" not in r:
                continue
            t = r["roofline_seconds"]
            print(f"| {arch} | {shape} | {mesh} | {fmt_t(t['compute'])} | "
                  f"{fmt_t(t['memory'])} | {fmt_t(t['collective'])} | "
                  f"{r['bottleneck']} |")
        print()


if __name__ == "__main__":
    main()
