"""F+Nomad LDA across 8 (faked) devices — the paper's distributed algorithm.

Run:  PYTHONPATH=src python examples/nomad_distributed.py [n_blocks]
                                                          [ring_mode]
                                                          [layout]
                                                          [doc_tile]
Documents sharded across an 8-worker ring; word-topic blocks travel the
ring as nomadic tokens — by default 4 blocks per worker (B = 4W, the
paper's blocks >> workers setup; pass n_blocks to override), with each
worker sweeping its whole block queue every ring round; the s-token
carries the global topic counts (paper Alg. 4).  ring_mode "pipelined"
(default; pass "barrier" to compare) forwards each round's first
half-queue while the second half sweeps — same chain bit-for-bit, hop
off the critical path.  layout "ragged" (default; pass "dense" to
compare) stores each worker's queue as a CSR-style tile stream, so
padding — and with it tokens/sec — no longer degrades as n_blocks
grows.  doc_tile (0 = off) pages (doc_tile, T) doc-topic slabs through
the fused kernels instead of holding each worker's whole (I_max, T)
shard in VMEM — the knob that lets per-worker documents scale past the
~12 MiB budget (DESIGN.md §7).  Prints LL per sweep + exactness check.
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import jax   # noqa: E402
import numpy as np  # noqa: E402

from repro.core.nomad import NomadLDA          # noqa: E402
from repro.data import synthetic               # noqa: E402
from repro.data.sharding import build_layout   # noqa: E402


def main():
    T = 32
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=600, vocab_size=1024, num_topics=T, mean_doc_len=50.0,
        seed=1)
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}; corpus: {corpus.num_tokens} tokens")

    n_blocks = int(sys.argv[1]) if len(sys.argv) > 1 else 4 * n_dev
    ring_mode = sys.argv[2] if len(sys.argv) > 2 else "pipelined"
    layout_kind = sys.argv[3] if len(sys.argv) > 3 else "ragged"
    doc_tile = int(sys.argv[4]) if len(sys.argv) > 4 else 0
    mesh = jax.make_mesh((n_dev,), ("worker",))
    doc_kw = {}
    if doc_tile:
        doc_kw = dict(doc_tile=doc_tile)
        if layout_kind == "dense":
            doc_kw["doc_blk"] = 16      # toy-corpus grid step (cf. N_BLK)
    layout = build_layout(corpus, n_workers=n_dev, T=T, n_blocks=n_blocks,
                          layout=layout_kind, **doc_kw)
    print(f"layout: {layout.W}x{layout.B} cells ({layout.k} blocks/queue, "
          f"{layout.kind}), pad {layout.pad_fraction:.1%},"
          f" worst-round imbalance {layout.round_imbalance:.2f}x,"
          f" ring_mode {ring_mode}"
          + (f", doc_tile {doc_tile} "
             f"({layout.ntd_slab_bytes} B slab vs "
             f"{layout.ntd_whole_bytes} B whole-shard)"
             if doc_tile else ""))

    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                   alpha=alpha, beta=beta, sync_mode="stoken",
                   ring_mode=ring_mode,
                   doc_tile=doc_tile if doc_tile else None)
    arrays = lda.init_arrays(seed=0)
    print(f"initial ll: {lda.log_likelihood(arrays):.0f}")
    for it in range(10):
        t0 = time.time()
        arrays = lda.sweep(arrays, seed=it)
        jax.block_until_ready(arrays["n_t"])
        ll = lda.log_likelihood(arrays)
        print(f"sweep {it + 1:2d}  ll {ll:.0f}  "
              f"({corpus.num_tokens / (time.time() - t0):,.0f} tok/s)")

    # exactness: rebuild counts from assignments
    n_td, n_wt, n_t = lda.global_counts(arrays)
    assert int(n_t.sum()) == corpus.num_tokens
    np.testing.assert_array_equal(n_td.sum(0), n_t)
    np.testing.assert_array_equal(n_wt.sum(0), n_t)
    print("count tables exact across the ring ✓")


if __name__ == "__main__":
    main()
