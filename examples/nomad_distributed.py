"""F+Nomad LDA across 8 (faked) devices — the paper's distributed algorithm.

Run:  PYTHONPATH=src python examples/nomad_distributed.py [n_blocks]
                                                          [ring_mode]
                                                          [layout]
                                                          [doc_tile]
          [--sweeps N] [--checkpoint-every N [--checkpoint-path PATH]]
          [--resume-from PATH]
Documents sharded across an 8-worker ring; word-topic blocks travel the
ring as nomadic tokens — by default 4 blocks per worker (B = 4W, the
paper's blocks >> workers setup; pass n_blocks to override), with each
worker sweeping its whole block queue every ring round; the s-token
carries the global topic counts (paper Alg. 4).  ring_mode "pipelined"
(default; pass "barrier" to compare) forwards each round's first
half-queue while the second half sweeps — same chain bit-for-bit, hop
off the critical path.  layout "ragged" (default; pass "dense" to
compare) stores each worker's queue as a CSR-style tile stream, so
padding — and with it tokens/sec — no longer degrades as n_blocks
grows.  doc_tile (0 = off) pages (doc_tile, T) doc-topic slabs through
the fused kernels instead of holding each worker's whole (I_max, T)
shard in VMEM — the knob that lets per-worker documents scale past the
~12 MiB budget (DESIGN.md §7).  --checkpoint-every writes a resumable
chain checkpoint (DESIGN.md §9) every N sweeps; --resume-from continues
a killed run bit-for-bit (the resumed chain is identical to an
uninterrupted one — pass the same layout args or the load refuses).
Prints LL per sweep + exactness check.
"""
import argparse
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import jax   # noqa: E402
import numpy as np  # noqa: E402

from repro.core.nomad import NomadLDA          # noqa: E402
from repro.data import synthetic               # noqa: E402
from repro.data.sharding import build_layout   # noqa: E402


def main():
    ap = argparse.ArgumentParser(
        description="F+Nomad LDA on a faked 8-device ring")
    ap.add_argument("n_blocks", nargs="?", type=int, default=0,
                    help="ring blocks B (default 4W)")
    ap.add_argument("ring_mode", nargs="?", default="pipelined",
                    choices=("pipelined", "barrier"))
    ap.add_argument("layout", nargs="?", default="ragged",
                    choices=("ragged", "dense"))
    ap.add_argument("doc_tile", nargs="?", type=int, default=0,
                    help="doc-topic slab height (0 = whole shard)")
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="write a chain checkpoint every N sweeps (0 = off)")
    ap.add_argument("--checkpoint-path", default="/tmp/nomad_chain.npz",
                    metavar="PATH")
    ap.add_argument("--resume-from", default=None, metavar="PATH",
                    help="resume bit-for-bit from a chain checkpoint")
    args = ap.parse_args()

    T = 32
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=600, vocab_size=1024, num_topics=T, mean_doc_len=50.0,
        seed=1)
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}; corpus: {corpus.num_tokens} tokens")

    n_blocks = args.n_blocks or 4 * n_dev
    mesh = jax.make_mesh((n_dev,), ("worker",))
    doc_kw = {}
    if args.doc_tile:
        doc_kw = dict(doc_tile=args.doc_tile)
        if args.layout == "dense":
            doc_kw["doc_blk"] = 16      # toy-corpus grid step (cf. N_BLK)
    layout = build_layout(corpus, n_workers=n_dev, T=T, n_blocks=n_blocks,
                          layout=args.layout, **doc_kw)
    print(f"layout: {layout.W}x{layout.B} cells ({layout.k} blocks/queue, "
          f"{layout.kind}), pad {layout.pad_fraction:.1%},"
          f" worst-round imbalance {layout.round_imbalance:.2f}x,"
          f" ring_mode {args.ring_mode}"
          + (f", doc_tile {args.doc_tile} "
             f"({layout.ntd_slab_bytes} B slab vs "
             f"{layout.ntd_whole_bytes} B whole-shard)"
             if args.doc_tile else ""))

    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                   alpha=alpha, beta=beta, sync_mode="stoken",
                   ring_mode=args.ring_mode,
                   doc_tile=args.doc_tile if args.doc_tile else None,
                   checkpoint_every=args.checkpoint_every or None,
                   checkpoint_path=(args.checkpoint_path
                                    if args.checkpoint_every else None),
                   resume_from=args.resume_from)
    if args.resume_from:
        print(f"resuming chain from {args.resume_from}")
    else:
        print(f"initial ll: "
              f"{lda.log_likelihood(lda.init_arrays(seed=0)):.0f}")

    t0 = [time.time()]

    def on_sweep(it, arrays):
        jax.block_until_ready(arrays["n_t"])
        ll = lda.log_likelihood(arrays)
        print(f"sweep {it + 1:2d}  ll {ll:.0f}  "
              f"({corpus.num_tokens / (time.time() - t0[0]):,.0f} tok/s)")
        t0[0] = time.time()

    arrays, _ = lda.run(args.sweeps, on_sweep=on_sweep)
    if args.checkpoint_every:
        print(f"chain checkpoint at {args.checkpoint_path} "
              f"(resume with --resume-from)")

    # exactness: rebuild counts from assignments
    n_td, n_wt, n_t = lda.global_counts(arrays)
    assert int(n_t.sum()) == corpus.num_tokens
    np.testing.assert_array_equal(n_td.sum(0), n_t)
    np.testing.assert_array_equal(n_wt.sum(0), n_t)
    print("count tables exact across the ring ✓")


if __name__ == "__main__":
    main()
