"""End-to-end driver (the paper's kind = training): F+Nomad LDA at scale.

Run:  PYTHONPATH=src python examples/train_lda_e2e.py [--sweeps 100]
          [--checkpoint-every 10] [--resume-from /tmp/repro_lda_ckpt.npz]
A few hundred sweeps of distributed F+Nomad LDA on a PubMed-scaled-down
synthetic corpus (T=64), with a resumable chain checkpoint (DESIGN.md §9)
every --checkpoint-every sweeps — kill the run and pass --resume-from to
continue bit-for-bit where it left off — the paper's Fig. 5/6 protocol
end to end.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

from repro.core.nomad import NomadLDA          # noqa: E402
from repro.data import synthetic               # noqa: E402
from repro.data.sharding import build_layout   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweeps", type=int, default=100)
    ap.add_argument("--topics", type=int, default=64)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--ckpt", default="/tmp/repro_lda_ckpt.npz")
    ap.add_argument("--checkpoint-every", type=int, default=10, metavar="N",
                    help="write a chain checkpoint every N sweeps (0 = off)")
    ap.add_argument("--resume-from", default=None, metavar="PATH",
                    help="resume bit-for-bit from a chain checkpoint")
    args = ap.parse_args()

    T = args.topics
    alpha, beta = 50.0 / T, 0.01
    corpus, _, _ = synthetic.make_corpus(
        num_docs=args.docs, vocab_size=2048, num_topics=T,
        mean_doc_len=80.0, seed=0)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("worker",))
    layout = build_layout(corpus, n_workers=n_dev, T=T)
    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=layout,
                   alpha=alpha, beta=beta, sync_mode="stoken",
                   checkpoint_every=args.checkpoint_every or None,
                   checkpoint_path=(args.ckpt if args.checkpoint_every
                                    else None),
                   resume_from=args.resume_from)

    print(f"{corpus.num_tokens:,} tokens on {n_dev} workers; "
          f"T={T}; {args.sweeps} sweeps"
          + (f"; resuming from {args.resume_from}"
             if args.resume_from else ""))
    t_start = time.time()
    done = [0]

    def on_sweep(it, arrays):
        done[0] += 1
        if (it + 1) % 10 == 0:
            jax.block_until_ready(arrays["n_t"])
            ll = lda.log_likelihood(arrays)
            rate = corpus.num_tokens * done[0] / (time.time() - t_start)
            print(f"sweep {it + 1:4d}  ll {ll:,.0f}  ({rate:,.0f} tok/s)")

    lda.run(args.sweeps, on_sweep=on_sweep)
    print(f"done in {time.time() - t_start:.1f}s"
          + (f"; chain checkpoint at {args.ckpt} "
             f"(resume with --resume-from)" if args.checkpoint_every else ""))


if __name__ == "__main__":
    main()
