"""Batched serving with the framework's engine (decode_32k's op in a loop).

Run:  PYTHONPATH=src python examples/serve_lm.py
Serves a (randomly initialized) smoke model: batched variable-length
prompts, prefill + greedy decode with per-sequence KV cache offsets.
"""
import time

import jax

from repro.configs import get_config
from repro.serve.engine import generate
from repro.train.train_step import init_train_state


def main():
    cfg = get_config("qwen3-8b").smoke()
    params = init_train_state(cfg, jax.random.key(0)).params
    prompts = [
        [11, 42, 7, 3, 99],
        [5, 6],
        [1, 2, 3, 4, 5, 6, 7, 8],
        [250],
    ]
    t0 = time.time()
    out = generate(params, cfg, prompts, max_new_tokens=8)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in out)
    for p, o in zip(prompts, out):
        print(f"prompt {p} -> {o}")
    print(f"{n_tok} tokens in {dt:.1f}s "
          f"(batch={len(prompts)}, variable lengths, one shared cache)")


if __name__ == "__main__":
    main()
