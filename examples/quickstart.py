"""Quickstart: serial F+LDA (paper Alg. 3) on a synthetic corpus.

Run:  PYTHONPATH=src python examples/quickstart.py
Trains word-by-word F+LDA for 20 sweeps, prints the log-likelihood
trajectory and the top words of a few topics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgs, likelihood
from repro.data import synthetic


def main():
    T = 16
    alpha, beta = 50.0 / T, 0.01
    corpus, _, phi_true = synthetic.make_corpus(
        num_docs=400, vocab_size=512, num_topics=T, mean_doc_len=60.0,
        seed=0)
    print(f"corpus: {corpus.num_docs} docs, {corpus.num_words} vocab, "
          f"{corpus.num_tokens} tokens, T={T}")

    doc_ids = jnp.asarray(corpus.doc_ids)
    word_ids = jnp.asarray(corpus.word_ids)
    order_np = corpus.word_order()
    order = jnp.asarray(order_np)
    boundary = jnp.asarray(corpus.word_boundary(order_np))

    sweep = jax.jit(lambda s: cgs.sweep_fplda_word(
        s, doc_ids, word_ids, order, boundary, alpha, beta))

    state = cgs.init_state(corpus, T, jax.random.key(0))
    print(f"initial ll/token: "
          f"{likelihood.per_token_ll(state, alpha, beta):.4f}")
    for it in range(20):
        state = sweep(state)
        if (it + 1) % 5 == 0:
            ll = likelihood.per_token_ll(state, alpha, beta)
            print(f"sweep {it + 1:3d}  ll/token {ll:.4f}")

    n_wt = np.asarray(state.n_wt)
    print("\ntop-6 words of first 4 topics:")
    for t in range(4):
        top = np.argsort(-n_wt[:, t])[:6]
        print(f"  topic {t}: {top.tolist()}  (counts {n_wt[top, t].tolist()})")


if __name__ == "__main__":
    main()
