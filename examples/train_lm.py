"""Train a small LM with the framework's neural substrate.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
Uses a reduced qwen3-family config (~15M params) on synthetic token data;
demonstrates the train_step / optimizer / checkpoint path the dry-run
lowers at production scale.  Loss must decrease.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.train import checkpoint
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, d_ff=1024,
                              vocab_size=2048)
    state = init_train_state(cfg, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"arch {cfg.name}: {n_params / 1e6:.1f}M params")

    step = jax.jit(make_train_step(cfg, lr=3e-4, remat=False))

    # synthetic data with learnable structure (bigram-ish chains)
    key = jax.random.key(1)
    t0 = time.time()
    first = last = None
    for it in range(args.steps):
        key, k1 = jax.random.split(key)
        start = jax.random.randint(k1, (args.batch, 1), 0, cfg.vocab_size)
        ramp = (start + jnp.arange(args.seq)[None, :] * 7) % cfg.vocab_size
        state, metrics = step(state, {"tokens": ramp})
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
        if (it + 1) % 25 == 0:
            print(f"step {it + 1:4d}  loss {last:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
    print(f"loss {first:.3f} -> {last:.3f} in {time.time() - t0:.0f}s")
    checkpoint.save(args.ckpt, state.params)
    print(f"checkpoint at {args.ckpt}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
