"""Online topic inference — train, publish φ, and serve θ queries live.

Run:  PYTHONPATH=src python examples/serve_topics.py [--sweeps N]
          [--publish-every N] [--queries N] [--batch N] [--save PATH]

The serving story end to end (DESIGN.md §10): a 4-worker F+Nomad ring
trains on a synthetic corpus and publishes a fresh φ snapshot into a
live :class:`LdaEngine` every ``--publish-every`` sweeps, while this
process keeps firing batched θ queries at the engine — double-buffered
φ, so no query ever observes a torn table.  Each answer prints the
snapshot generation it folded against, its latency, and the top topic
per document.  ``--save`` additionally round-trips the final snapshot
through the format-versioned ``save_phi``/``load_phi`` store.
"""
import argparse
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import threading  # noqa: E402

import jax        # noqa: E402
import numpy as np  # noqa: E402

from repro.core.nomad import NomadLDA            # noqa: E402
from repro.data import synthetic                 # noqa: E402
from repro.data.sharding import build_layout     # noqa: E402
from repro.serve.lda_engine import (LdaEngine, PhiSnapshot,  # noqa: E402
                                    TopicQuery)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--sweeps", type=int, default=9)
    p.add_argument("--publish-every", type=int, default=3)
    p.add_argument("--queries", type=int, default=12)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--save", default="")
    args = p.parse_args()

    T = 8
    corpus, true_phi, _ = synthetic.make_corpus(
        num_docs=120, vocab_size=128, num_topics=T, mean_doc_len=30.0,
        seed=0)
    mesh = jax.make_mesh((4,), ("worker",))
    lay = build_layout(corpus, n_workers=4, T=T, n_blocks=8,
                       layout="ragged")
    lda = NomadLDA(mesh=mesh, ring_axes=("worker",), layout=lay,
                   alpha=50.0 / T, beta=0.01, sync_mode="stoken",
                   inner_mode="scan")

    engine = LdaEngine(sweeps=5, tile=8, max_batch=64)
    engine.publish(lda.export_phi_snapshot(lda.init_arrays(seed=0),
                                           sweep=0))
    print(f"serving opened at generation {engine.generation} "
          f"(init counts)")

    latest = {}

    def on_publish(snap):
        gen = engine.publish(snap)
        latest["snap"], latest["gen"] = snap, gen
        print(f"  [ring] published sweep-{snap.meta['sweep']} snapshot "
              f"-> generation {gen} ({snap.digest[:12]}...)")

    trainer = threading.Thread(
        target=lda.run, args=(args.sweeps,),
        kwargs=dict(init_seed=0, publish_every=args.publish_every,
                    on_publish=on_publish),
        daemon=True)
    trainer.start()

    rng = np.random.default_rng(1)
    words = np.unique(np.asarray(corpus.word_ids))
    i = 0
    while i < args.queries or trainer.is_alive():
        docs = tuple(
            rng.choice(words, size=int(n), replace=True).astype(np.int32)
            for n in rng.integers(1, 25, size=args.batch))
        res = engine.query(TopicQuery(docs=docs, key=jax.random.key(i)))
        top = np.argmax(res.theta, axis=1)
        print(f"query {i:3d}: gen {res.generation}, "
              f"{res.latency_s * 1e3:6.1f} ms, "
              f"batch {res.batch_shape}, top topics {top.tolist()}")
        i += 1
    trainer.join()

    if args.save and latest:
        latest["snap"].save(args.save)
        back = PhiSnapshot.load(args.save)
        print(f"snapshot saved to {args.save} and reloaded "
              f"(digest {back.digest[:12]}..., generation {latest['gen']})")


if __name__ == "__main__":
    main()
